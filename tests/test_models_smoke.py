"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run one forward + one train-gradient step on CPU, assert output
shapes and absence of NaNs.  Also check prefill+decode consistency against
the teacher-forced forward for every family (serving correctness)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import LM_SHAPES
from repro.models.registry import ARCH_IDS, get_config, get_model, \
    supported_shapes


def make_batch(model, cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        n_img = cfg.vlm.n_image_tokens
        batch["img_embeds"] = 0.1 * jax.random.normal(
            ks[1], (B, n_img, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            ks[1], (B, S // 2, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert model.param_count() > 0
    batch = make_batch(model, cfg, jax.random.PRNGKey(1))
    B, S = batch["tokens"].shape

    # forward: correct logits shape, finite
    if cfg.family == "audio":
        logits, _ = model.forward(params, batch["tokens"], batch["frames"])
    elif cfg.family == "vlm":
        logits, _ = model.forward(params, batch["tokens"],
                                  batch["img_embeds"])
    else:
        logits, _ = model.forward(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one train step: loss finite, grads finite and nonzero somewhere
    def loss_fn(p):
        loss, _ = model.loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{arch}: non-finite grads"
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), \
        f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_matches_forward(arch):
    """decode(prefill(prompt)) logits == teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    B, S = tokens.shape

    total = S
    if cfg.family == "audio":
        args = (tokens, batch["frames"])
    elif cfg.family == "vlm":
        args = (tokens, batch["img_embeds"])
        total += cfg.vlm.n_image_tokens   # cache must cover the image prefix
    else:
        args = (tokens,)

    last, caches = model.prefill(params, *args, max_len=total + 4)
    full, _ = model.forward(params, *args)
    assert jnp.allclose(last, full[:, -1], atol=2e-3), \
        f"{arch}: prefill logits diverge " \
        f"{float(jnp.abs(last - full[:, -1]).max())}"

    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    step_logits, caches = model.decode_step(params, nxt, caches)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    full2, _ = model.forward(params, ext, *args[1:])
    assert jnp.allclose(step_logits, full2[:, -1], atol=2e-3), \
        f"{arch}: decode logits diverge " \
        f"{float(jnp.abs(step_logits - full2[:, -1]).max())}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_abstractly(arch):
    """FULL configs: eval_shape only (no allocation) — exact assigned dims."""
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = model.param_shapes()
    n = model.param_count()
    assert n > 0
    # spot-check assigned dimensions
    assert cfg.d_model == {"kimi-k2-1t-a32b": 7168, "mixtral-8x22b": 6144,
                           "phi3-medium-14b": 5120, "qwen3-32b": 5120,
                           "yi-9b": 4096, "qwen1.5-32b": 5120,
                           "llava-next-34b": 7168, "whisper-small": 768,
                           "xlstm-125m": 768,
                           "recurrentgemma-2b": 2560}[arch]
    specs = model.param_specs()
    # every param leaf got a spec
    assert len(jax.tree.leaves(shapes)) == len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
        x.__class__.__name__ == "PartitionSpec"))


def test_param_scale_sanity():
    """Full-config param counts are in the advertised ballpark."""
    expected = {
        "kimi-k2-1t-a32b": (0.9e12, 1.3e12),
        "mixtral-8x22b": (1.2e11, 1.6e11),
        "phi3-medium-14b": (1.1e10, 1.7e10),
        "qwen3-32b": (2.6e10, 4.0e10),
        "yi-9b": (7e9, 1.1e10),
        "qwen1.5-32b": (2.8e10, 4.2e10),
        "llava-next-34b": (2.8e10, 4.1e10),
        "whisper-small": (1.2e8, 3.0e8),
        "xlstm-125m": (0.9e8, 2.2e8),
        "recurrentgemma-2b": (2.0e9, 3.6e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = get_model(cfg).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"


def test_kimi_active_params_about_32b():
    cfg = get_config("kimi-k2-1t-a32b")
    m = get_model(cfg)
    a = m.active_param_count()
    assert 2.0e10 <= a <= 4.5e10, f"active {a:.3e}"


def test_supported_shapes_long500k_rules():
    runs_long = {a: "long_500k" in supported_shapes(get_config(a))
                 for a in ARCH_IDS}
    assert runs_long == {
        "kimi-k2-1t-a32b": False, "mixtral-8x22b": True,
        "phi3-medium-14b": False, "qwen3-32b": False, "yi-9b": False,
        "qwen1.5-32b": False, "llava-next-34b": False,
        "whisper-small": False, "xlstm-125m": True,
        "recurrentgemma-2b": True,
    }
